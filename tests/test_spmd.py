"""Multi-device SPMD tests — run in a subprocess so the 8 fake host
devices never leak into the other tests' single-device world."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shard_map_coded_grads_match_uncoded():
    res = _run(textwrap.dedent("""
        import json, jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import ShiftedExponential
        from repro.dist.sharding import use_mesh, make_rules
        from repro.train.state import init_train_state
        from repro.train.coded import build_plan, make_coded_grad_fn, uncoded_grad_fn, StragglerSim
        from repro.data.pipeline import DataConfig, SyntheticTokens, coded_worker_batches
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("gc-lm-110m").reduced(n_layers=2, d_model=128)
        state, _ = init_train_state(cfg, jax.random.PRNGKey(0))
        dist = ShiftedExponential(mu=1e-3, t0=50.0)
        plan = build_plan(state.params, dist, 4, solver="xf")
        data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=48, global_batch=8))
        wb = jnp.asarray(coded_worker_batches(data, 0, 4, plan.s_max))
        dec_w, _ = StragglerSim(plan, dist, seed=1).step()
        with use_mesh(mesh, make_rules(cfg)):
            g = jax.jit(make_coded_grad_fn(cfg, plan, mesh=mesh, mode="spmd"))(state.params, wb, dec_w)
            shards = jnp.asarray(np.stack([data.shard(0, i, 4) for i in range(4)]))
            g_ref = jax.jit(uncoded_grad_fn(cfg, 4))(state.params, shards)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), g, g_ref)))
        print(json.dumps({"err": err, "devices": len(jax.devices())}))
    """))
    assert res["devices"] == 8
    assert res["err"] < 1e-4


def test_pjit_train_step_runs_sharded():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist.sharding import use_mesh, make_rules, pspec_for_axes
        from repro.train.state import init_train_state, state_shardings
        from repro.train.trainer import TrainConfig, make_train_step
        import numpy as np
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("gemma3-27b").reduced(n_layers=2, d_model=256)
        state, axes = init_train_state(cfg, jax.random.PRNGKey(0))
        with use_mesh(mesh, make_rules(cfg)):
            step = jax.jit(make_train_step(cfg, TrainConfig()))
            batch = {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab, (8, 65)), jnp.int32)}
            state2, metrics = step(state, batch)
        print(json.dumps({"loss": float(metrics["loss"]),
                          "step": int(state2.step)}))
    """))
    assert res["step"] == 1
    assert res["loss"] > 0 and res["loss"] == res["loss"]  # finite


def test_serve_step_sharded_decode():
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.dist.sharding import use_mesh, make_rules
        from repro.models.model import init_model, init_decode_caches
        from repro.serve.engine import make_serve_step
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("gemma2-27b").reduced(n_layers=2, d_model=256)
        params, _ = init_model(cfg, jax.random.PRNGKey(0))
        with use_mesh(mesh, make_rules(cfg)):
            caches = init_decode_caches(cfg, 8, 128, dtype=jnp.float32)
            serve = jax.jit(make_serve_step(cfg))
            tok = jnp.zeros((8, 1), jnp.int32)
            logits, caches = serve(params, caches, tok)
        print(json.dumps({"shape": list(logits.shape)}))
    """))
    assert res["shape"] == [8, 512]  # reduced() sets vocab=512
