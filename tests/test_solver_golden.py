"""Golden-partition regression suite (ISSUE 5): the solver outputs at
the paper's Fig. 3 / Fig. 4(a) operating points, pinned to exact block
vectors.  A change in water-filling arithmetic, order-statistic closed
forms, quadrature, or largest-remainder rounding now fails tier-1
instead of silently shifting the benchmark curves.

Settings (paper §VI): T ~ shifted-exponential(mu=1e-3, t0=50),
L = 20000 coordinates; Fig. 3 pins N=20, Fig. 4(a) pins the N=10 and
N=50 endpoints.  The integer vectors go through the registry path
(``solve_scheme`` -> largest-remainder rounding), the continuous ones
through ``solve_xt``/``solve_xf`` directly.
"""
import numpy as np
import pytest

from repro.core import ShiftedExponential, solve_scheme
from repro.core.solvers import closed_form_x, closed_form_x_capped, solve_xf, solve_xt

DIST = ShiftedExponential(mu=1e-3, t0=50.0)
L = 20_000

# ------------------------------------------------------------- golden data
# Integer partitions via the registry (solve_scheme), Fig. 3 / Fig. 4(a).
GOLDEN_INT = {
    ("xt", 20): [5697, 1076, 609, 444, 366, 324, 303, 294, 294, 303, 321,
                 349, 390, 448, 534, 663, 868, 1222, 1912, 3583],
    ("xf", 20): [5519, 939, 550, 408, 340, 305, 287, 282, 285, 297, 319,
                 351, 398, 466, 565, 715, 953, 1356, 2091, 3574],
    ("xt", 10): [5583, 1411, 947, 818, 811, 890, 1076, 1454, 2291, 4719],
    ("xf", 10): [5060, 1186, 837, 751, 773, 886, 1126, 1604, 2629, 5148],
    ("xt", 50): [6896, 971, 483, 316, 234, 187, 157, 136, 122, 111, 102,
                 96, 91, 87, 84, 82, 80, 79, 78, 78, 78, 79, 80, 81, 83,
                 85, 88, 91, 94, 99, 104, 109, 116, 124, 133, 144, 156,
                 171, 190, 212, 239, 273, 317, 374, 451, 557, 710, 943,
                 1326, 2023],
    ("xf", 50): [7000, 882, 452, 299, 224, 179, 151, 132, 118, 107, 99,
                 93, 89, 85, 82, 80, 79, 78, 77, 77, 78, 78, 79, 81, 83,
                 85, 88, 91, 95, 99, 105, 111, 118, 126, 136, 148, 161,
                 177, 197, 221, 251, 288, 335, 397, 478, 590, 747, 975,
                 1323, 1876],
}

# Continuous Theorem-2/3 solutions at the Fig. 3 point (N=20); exact
# float64 water-filling values (xt is closed-form eq. (11) order stats,
# xf goes through the Beta-reparameterized quadrature).
GOLDEN_XT_CONT_N20 = [
    5696.557723115543, 1075.7397744423358, 609.015253617646,
    444.3640373294377, 366.03467423592696, 324.50530123255805,
    302.7477288636193, 293.6850148142708, 294.2196442182209,
    303.2515858191589, 320.98903420906146, 348.8246770147917,
    389.6066190814519, 448.3983108962266, 534.130572687587,
    663.2328059044459, 868.2991028882132, 1221.6095423629733,
    1912.1059221257067, 3582.6826751408257,
]
GOLDEN_XF_CONT_N20 = [
    5519.341174324576, 939.2088983124461, 549.4718387919464,
    407.9521576870649, 340.29079146080016, 304.9393575294875,
    287.3873515736868, 281.62318075374077, 285.12759365010487,
    297.2140473923507, 318.48858771634787, 350.8340372008412,
    397.80509387539405, 465.57532683207137, 564.893797936943,
    715.1708548065908, 953.4695211273325, 1355.731676940184,
    2091.132341915607, 3574.342370172482,
]

# Level-capped water-filling (s_cap=3) at the Fig. 3 point: all mass on
# levels 0..3, the cap level absorbing the truncated tail's residual.
GOLDEN_CAPPED_S3_N20 = [
    14558.632760001396, 2749.256846442921, 1556.4538891057211,
    1135.6565044499623, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
    0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
]


@pytest.mark.parametrize("scheme,n", sorted(GOLDEN_INT))
def test_registry_partitions_pinned(scheme, n):
    x = solve_scheme(scheme, DIST, n, L)
    assert x.dtype == np.int64
    assert int(x.sum()) == L
    np.testing.assert_array_equal(x, np.asarray(GOLDEN_INT[scheme, n]))


def test_solve_xt_continuous_pinned():
    x = solve_xt(DIST, 20, float(L))
    # eq. (11) closed-form order stats + exact water-filling: float64-tight
    np.testing.assert_allclose(x, GOLDEN_XT_CONT_N20, rtol=1e-12, atol=0)


def test_solve_xf_continuous_pinned():
    x = solve_xf(DIST, 20, float(L))
    # Lemma-2 values come from adaptive quadrature: pin to 1e-9 relative
    # (far below any partition-shifting change, above platform noise)
    np.testing.assert_allclose(x, GOLDEN_XF_CONT_N20, rtol=1e-9, atol=0)


def test_closed_form_x_capped_pinned():
    t = DIST.expected_order_stats(20)
    x = closed_form_x_capped(t, float(L), 3)
    np.testing.assert_allclose(x, GOLDEN_CAPPED_S3_N20, rtol=1e-12, atol=0)
    assert x.sum() == pytest.approx(L, abs=1e-9)
    # the cap is respected: no mass above level 3
    assert (x[4:] == 0.0).all()
    # and the uncapped call reduces to closed_form_x exactly
    np.testing.assert_array_equal(closed_form_x_capped(t, float(L), 19),
                                  closed_form_x(t, float(L)))


def test_water_filling_equalizes_max_terms():
    """Structural invariant behind the golden values: Theorem 2's x
    equalizes every max-term of eq. (5) at the deterministic t."""
    t = DIST.expected_order_stats(20)
    x = closed_form_x(t, float(L))
    n = np.arange(20)
    work = np.cumsum((n + 1.0) * x)
    terms = t[::-1] * work  # T_(N-n) * S_n
    np.testing.assert_allclose(terms, terms[0], rtol=1e-9)
