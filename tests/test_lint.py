"""repro.lint: the contract linter's own tier-1 suite.

Three layers:
  * fixture pairs — every rule RL001-RL007 fires on its ``rlNNN_bad.py``
    counterexample and stays quiet on the blessed ``rlNNN_good.py``
    idioms (tests/lint_fixtures/; linted under a virtual src/repro path
    so the path-scoped rules are in scope);
  * machinery — suppressions, baseline matching/staleness, traced-
    context propagation, the RL000 syntax-error funnel, the CLI;
  * the repo itself — ``src tests benchmarks`` lints clean against the
    committed baseline, with no stale baseline entries, and the
    hygiene checks (RH001-RH003) pass.  This is the gate that keeps
    every future PR on the contracts.
"""
import json
from pathlib import Path

import pytest

from repro.lint import (Baseline, Finding, lint_paths, lint_source,
                        run_hygiene)
from repro.lint.cli import main as lint_main
from repro.lint.engine import iter_python_files
from repro.lint.rules import RULES

REPO = Path(__file__).resolve().parents[1]
FIXDIR = Path(__file__).resolve().parent / "lint_fixtures"
RULE_IDS = [r.id for r in RULES]


def lint_fixture(name, baseline=None):
    """Lint a fixture under a virtual src/repro path so the path-scoped
    rules (RL006/RL007, RL001's non-test half) apply."""
    return lint_source((FIXDIR / name).read_text(),
                       f"src/repro/fixture/{name}", baseline=baseline)


# ------------------------------------------------------------ fixture pairs
def test_rule_catalogue_is_complete():
    assert RULE_IDS == [f"RL{i:03d}" for i in range(1, 8)]


@pytest.mark.parametrize("rule_id", [f"RL{i:03d}" for i in range(1, 8)])
def test_bad_fixture_fires_only_its_rule(rule_id):
    findings = lint_fixture(f"{rule_id.lower()}_bad.py")
    assert findings, f"{rule_id} counterexample produced no findings"
    assert {f.rule for f in findings} == {rule_id}


@pytest.mark.parametrize("rule_id", [f"RL{i:03d}" for i in range(1, 8)])
def test_good_fixture_is_clean(rule_id):
    findings = lint_fixture(f"{rule_id.lower()}_good.py")
    assert findings == [], [f.render() for f in findings]


def test_findings_carry_location_and_message():
    f = lint_fixture("rl001_bad.py")[0]
    assert f.path == "src/repro/fixture/rl001_bad.py"
    assert f.line > 0
    assert "RL001" in f.render() and str(f.line) in f.render()
    assert set(f.to_dict()) >= {"rule", "path", "line", "col", "message"}


# ------------------------------------------------------------- suppressions
def test_suppression_comment_silences_both_placements():
    assert lint_fixture("suppressed.py") == []


def test_without_suppression_the_same_code_fires():
    src = (FIXDIR / "suppressed.py").read_text().replace(
        "repro-lint: disable=RL001", "")
    findings = lint_source(src, "src/repro/fixture/suppressed.py")
    assert {f.rule for f in findings} == {"RL001"}
    assert len(findings) == 2


# ----------------------------------------------------------------- baseline
def test_baseline_grandfathers_matching_findings():
    bl = Baseline([{"rule": "RL007", "path": "src/repro/fixture/rl007_bad.py",
                    "match": "env", "justification": "fixture"}])
    assert lint_fixture("rl007_bad.py", baseline=bl) == []
    assert bl.unused() == []


def test_baseline_reports_stale_entries():
    bl = Baseline([{"rule": "RL001", "path": "src/repro/nope.py",
                    "justification": "stale"}])
    lint_fixture("rl007_bad.py", baseline=bl)
    assert [e["path"] for e in bl.unused()] == ["src/repro/nope.py"]


def test_baseline_entries_require_a_justification():
    with pytest.raises(ValueError, match="justification"):
        Baseline([{"rule": "RL001", "path": "x.py"}])


# ---------------------------------------------------------------- machinery
def test_syntax_error_becomes_rl000_finding():
    findings = lint_source("def broken(:\n", "src/repro/broken.py")
    assert [f.rule for f in findings] == ["RL000"]


def test_traced_context_propagates_through_local_calls():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "def helper(x):\n"
        "    return x * np.random.uniform()\n"
        "def entry(x):\n"
        "    return jax.jit(lambda v: helper(v))\n"
    )
    findings = lint_source(src, "scratch.py")
    assert any(f.rule == "RL002" and "np.random" in f.message
               for f in findings)


def test_tree_walk_skips_the_fixture_directory():
    files = iter_python_files([REPO / "tests"])
    assert files, "tests directory should contain python files"
    assert not any("lint_fixtures" in str(p) for p in files)


# ---------------------------------------------------------------------- CLI
def test_cli_exit_one_and_json_on_findings(capsys, monkeypatch):
    monkeypatch.chdir(REPO)
    rc = lint_main([str(FIXDIR / "rl002_bad.py"), "--json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["count"] == len(payload["findings"]) > 0
    assert {f["rule"] for f in payload["findings"]} == {"RL002"}


def test_cli_exit_zero_on_clean_path(capsys, monkeypatch):
    monkeypatch.chdir(REPO)
    rc = lint_main([str(FIXDIR / "rl002_good.py")])
    assert rc == 0
    assert "lint clean" in capsys.readouterr().out


# ------------------------------------------------------- the repo is clean
def test_repo_lints_clean_against_committed_baseline():
    baseline = Baseline.load(REPO / "lint-baseline.json")
    findings = lint_paths([REPO / "src", REPO / "tests", REPO / "benchmarks"],
                          baseline=baseline, relative_to=REPO)
    assert findings == [], "\n".join(f.render() for f in findings)
    stale = baseline.unused()
    assert stale == [], f"stale baseline entries: {stale}"


def test_committed_baseline_is_empty():
    """The last grandfathered finding (serve/engine.py's in-trace
    ``_TRACE_COUNTS``) was replaced by the derived-signature counter;
    the baseline must stay empty — a new entry needs a justification
    AND a reviewer deliberately deleting this test's guarantee."""
    blob = json.loads((REPO / "lint-baseline.json").read_text())
    assert blob == [], f"lint-baseline.json regained entries: {blob}"


def test_repo_hygiene_is_clean():
    findings = run_hygiene(REPO)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_hygiene_mode(capsys, monkeypatch):
    monkeypatch.chdir(REPO)
    rc = lint_main(["--hygiene"])
    assert rc == 0
    assert "hygiene clean" in capsys.readouterr().out
