"""Coded decode tier (ISSUE 6): solver, event-order exactness, closed forms.

The serving tier's step latency L(R, s) = (s+1)/R * c * T_(R-s:R) is
the paper's block-decode event applied to one inference step.  These
tests pin the three contracts that make it trustworthy:

* ``step_latency`` realizes *exactly* the event order of a one-block
  ``ClusterSim`` schedule at level s over R workers (same times, same
  completion instant);
* the measured p99 of a long seeded stream agrees with
  ``Env.order_stat_quantile`` — the Poisson-binomial tail DP — and with
  the ShiftedExponential analytic quantiles where those exist;
* the (R, s) solver is exact for its tiny enumeration space.
"""
import numpy as np
import pytest

from repro.core.distributions import ShiftedExponential, UniformStraggler
from repro.core.env import Env
from repro.core.runtime import CostModel
from repro.serve.coded import CodedDecode, ReplicationPlan, solve_replication
from repro.sim.cluster import Block, ClusterConfig, ClusterSim

DIST = ShiftedExponential(mu=1e-3, t0=50.0)


def _env(n=8):
    return Env.iid(DIST, n)


# ----------------------------------------------------------------- solver
def test_budget_one_is_the_uncoded_baseline():
    plan = solve_replication(_env(), budget=1, objective="p99")
    assert (plan.r, plan.s) == (1, 0)
    assert plan.work_factor == 1.0 and plan.need == 1


def test_solver_beats_uncoded_p99_on_heavy_tail():
    env = _env()
    plan = solve_replication(env, budget=4, objective="p99")
    base = solve_replication(env, budget=1, objective="p99")
    assert plan.r > 1
    assert plan.p99_step < base.p99_step / 2, (
        "replication must cut the exponential tail's p99 substantially")


def test_solver_is_exact_over_its_enumeration():
    env = _env(6)
    best = solve_replication(env, budget=4, objective="mean")
    # brute-force the same space independently
    scores = {}
    for r in range(1, 5):
        sub = env.subset(range(r))
        stats = sub.expected_order_stats()
        for s in range(r):
            scores[(r, s)] = (s + 1) / r * float(stats[r - s - 1])
    assert (best.r, best.s) == min(scores, key=scores.get)
    assert best.expected_step == pytest.approx(min(scores.values()))


def test_solver_validation():
    env = _env(4)
    with pytest.raises(ValueError):
        solve_replication(env, budget=5)
    with pytest.raises(ValueError):
        solve_replication(env, budget=0)
    with pytest.raises(ValueError):
        solve_replication(env, objective="fastest")


def test_plan_roundtrip_and_validation():
    plan = solve_replication(_env(), budget=3, objective="p99")
    again = ReplicationPlan.from_dict(plan.to_dict())
    assert again == plan
    with pytest.raises(ValueError):
        ReplicationPlan(r=2, s=2, workers=(0, 1), objective="p99",
                        expected_step=1.0, p99_step=1.0)
    with pytest.raises(ValueError):
        ReplicationPlan(r=2, s=0, workers=(0,), objective="p99",
                        expected_step=1.0, p99_step=1.0)


def test_coded_decode_roundtrip():
    tier = CodedDecode.solve(_env(), budget=4, work=3.0, seed=5)
    again = CodedDecode.from_dict(tier.to_dict())
    assert again.plan == tier.plan and again.work == tier.work
    np.testing.assert_allclose(again.step_latencies(64, seed=3),
                               tier.step_latencies(64, seed=3))


# ----------------------------------- first-(R-s) exactness vs the event engine
@pytest.mark.parametrize("r,s", [(1, 0), (2, 0), (4, 0), (4, 2), (4, 3),
                                 (6, 2), (6, 5)])
def test_step_latency_matches_cluster_sim_event_order(r, s):
    """A coded decode step *is* a one-block schedule at level s over R
    workers: per-worker work (s+1)*c under CostModel scale 1/R, decoded
    at the (R-s)-th delivery.  The tier's arithmetic must match the
    discrete-event makespan exactly for the same drawn times."""
    rng = np.random.default_rng(100 * r + s)
    c = 2.5
    plan = ReplicationPlan(r=r, s=s, workers=tuple(range(r)),
                           objective="p99", expected_step=0.0, p99_step=0.0)
    tier = CodedDecode(_env(r), plan, work=c)
    for _ in range(5):
        times = DIST.sample(rng, (r,))
        sim = ClusterSim((Block(index=0, level=s, work=(s + 1) * c),),
                         times[None, :], r,
                         cost=CostModel(m_samples=1, b_cycles=1.0),
                         config=ClusterConfig(wave=False))
        res = sim.run(rounds=1, times=times[None, :])
        assert tier.step_latency(times) == pytest.approx(
            float(res.makespan), rel=1e-12)


def test_step_latency_validates_shape():
    tier = CodedDecode.solve(_env(), budget=3)
    with pytest.raises(ValueError):
        tier.step_latency(np.ones(tier.plan.r + 1))


# ----------------------------------------------- seeded streams + closed forms
def test_seeded_stream_replays_exactly():
    env = _env()
    a = CodedDecode.solve(env, budget=4, seed=9)
    b = CodedDecode.solve(env, budget=4, seed=9)
    np.testing.assert_array_equal(a.step_latencies(100), b.step_latencies(100))
    # the instance stream advances: successive draws differ
    assert a.draw_step() != a.draw_step()


def test_measured_p99_matches_order_stat_closed_form():
    """The acceptance-criteria agreement check: p99 of a seeded latency
    stream vs the Env order-statistics prediction."""
    tier = CodedDecode.solve(_env(), budget=4, objective="p99", seed=0)
    lat = tier.step_latencies(50_000, seed=13)
    measured = float(np.quantile(lat, 0.99))
    predicted = tier.predicted_quantile(0.99)
    assert abs(measured - predicted) / predicted < 0.05
    # mean agrees too (much lower MC noise)
    assert float(lat.mean()) == pytest.approx(tier.predicted_mean(), rel=0.02)


def test_order_stat_quantile_analytic_shifted_exponential():
    """Env.order_stat_quantile vs the ShiftedExponential analytic
    quantiles: min of N iid is t0 + Exp(N mu); max of N iid inverts
    F(t)^N = q."""
    n, q = 4, 0.99
    env = _env(n)
    t_min = env.order_stat_quantile(1, q)
    expect_min = 50.0 - np.log(1 - q) / (n * 1e-3)
    assert t_min == pytest.approx(expect_min, rel=1e-4)
    t_max = env.order_stat_quantile(n, q)
    expect_max = 50.0 - np.log(1.0 - q ** (1.0 / n)) / 1e-3
    assert t_max == pytest.approx(expect_max, rel=1e-4)


def test_env_subset_reindexes_population():
    dists = [ShiftedExponential(mu=1e-3, t0=float(t0))
             for t0 in (10.0, 20.0, 30.0, 40.0)]
    env = Env.heterogeneous(dists)
    sub = env.subset([2, 0])
    assert sub.n_workers == 2
    assert sub.dists == (dists[2], dists[0])
    with pytest.raises(ValueError):
        env.subset([])
    with pytest.raises(ValueError):
        env.subset([4])


def test_uncoded_tier_prices_the_single_worker():
    tier = CodedDecode.uncoded(_env(), work=2.0)
    assert (tier.plan.r, tier.plan.s) == (1, 0)
    assert tier.predicted_mean() == pytest.approx(2.0 * (50.0 + 1e3), rel=1e-6)


def test_solver_picks_fastest_workers_in_heterogeneous_env():
    dists = [ShiftedExponential(mu=1e-3, t0=float(t0))
             for t0 in (400.0, 10.0, 300.0, 20.0, 500.0, 30.0)]
    env = Env.heterogeneous(dists)
    plan = solve_replication(env, budget=3, objective="mean")
    assert set(plan.workers) <= {1, 3, 5}, (
        "the replica group must be drawn from the fastest workers")


def test_bounded_support_env_prefers_low_redundancy():
    """With a light-tailed (uniform) population, heavy replication has
    little to buy at the mean; the solver must not pay (s+1) work
    multipliers it cannot recoup."""
    env = Env.iid(UniformStraggler(lo=90.0, hi=110.0), 8)
    plan = solve_replication(env, budget=4, objective="mean")
    assert plan.expected_step <= 110.0  # never worse than one worker's worst
