"""Adaptive re-planning (ISSUE 5): online estimator consistency, drift
detection bounds, and — the load-bearing guarantee — that a mid-run
Plan hot-swap is provably non-invasive: swapping away and back is
bit-identical to never swapping, a swap to plan B equals a fresh run
that started on B at that step, and optimizer/RNG state hashes are
unchanged across a no-op swap.  Sim-mode here; the spmd twin (psum and
psum_scatter) runs in the subprocess test marked ``spmd``.
"""
import hashlib
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.adapt import AdaptConfig, AdaptiveController, RuntimeMonitor
from repro.adapt.monitor import ks_2sample, ks_threshold
from repro.core import Env, Plan, ShiftedExponential, solve_scheme, spsg
from repro.core.runtime import tau_hat_batch

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DIST = ShiftedExponential(mu=1e-3, t0=50.0)
N = 8


# ----------------------------------------------------------- monitor basics
def test_monitor_validates_observations():
    mon = RuntimeMonitor(4)
    with pytest.raises(ValueError, match="per-worker"):
        mon.observe(np.ones(3))
    with pytest.raises(ValueError, match="finite and positive"):
        mon.observe(np.array([1.0, -1.0, 2.0, 3.0]))
    with pytest.raises(ValueError, match="finite and positive"):
        mon.observe(np.array([1.0, np.inf, 2.0, 3.0]))
    mon.observe(np.ones(4))
    assert len(mon) == 1 and mon.rounds_seen == 1
    mon.reset()
    assert len(mon) == 0 and mon.rounds_seen == 1  # rounds_seen is global
    # wall-clock ingestion (the spmd-mode path): end - start per rank
    mon.observe_wallclock(10.0, np.array([11.0, 12.0, 11.5, 13.0]))
    np.testing.assert_array_equal(mon.window_times()[-1],
                                  [1.0, 2.0, 1.5, 3.0])


def test_ks_statistic_matches_brute_force():
    rng = np.random.default_rng(0)
    a, b = rng.standard_normal(37), rng.standard_normal(53) + 0.5
    grid = np.concatenate([a, b])
    brute = max(abs((a <= t).mean() - (b <= t).mean()) for t in grid)
    assert ks_2sample(a, b) == pytest.approx(brute, abs=1e-12)
    # threshold shrinks with more data, grows with smaller alpha
    assert ks_threshold(64, 64, 0.01) < ks_threshold(16, 16, 0.01)
    assert ks_threshold(64, 64, 0.001) > ks_threshold(64, 64, 0.01)


# ----------------------------------------------------- estimator consistency
def test_online_env_estimate_converges_to_closed_forms():
    """Stationary seeded ShiftedExponential stream -> the estimated
    Env's order statistics match the paper's closed forms (eq. (11) /
    Lemma 2) within MC+bootstrap tolerance."""
    mon = RuntimeMonitor(N, window=4000, min_rounds=100, mc_samples=60_000)
    mon.observe_many(DIST.sample(np.random.default_rng(7), (4000, N)))
    env_hat = mon.estimated_env()
    assert isinstance(env_hat, Env) and env_hat.n_workers == N
    t_err = np.abs(env_hat.expected_order_stats(N, rng=1)
                   / DIST.expected_order_stats(N) - 1.0).max()
    tp_err = np.abs(env_hat.inv_expected_inv_order_stats(N, rng=1)
                    / DIST.inv_expected_inv_order_stats(N) - 1.0).max()
    assert t_err < 0.05, t_err
    assert tp_err < 0.05, tp_err


def test_drift_detector_quiet_on_stationary_and_fires_within_window():
    window = 64
    mon = RuntimeMonitor(N, window=window, min_rounds=window // 2)
    rng = np.random.default_rng(3)
    fired_stationary = False
    for r in range(400):
        mon.observe(DIST.sample(rng, (N,)))
        if r % 4 == 0 and mon.drift():
            fired_stationary = True
    assert not fired_stationary, "drift fired on a stationary stream"
    # step change: two workers 4x slower -> must fire within `window`
    fired_after = None
    for r in range(window + 1):
        t = DIST.sample(rng, (N,))
        t[:2] *= 4.0
        mon.observe(t)
        if mon.drift():
            fired_after = r + 1
            break
    assert fired_after is not None and fired_after <= window, fired_after
    assert mon.drift().worker in (0, 1)


def test_cumulative_shift_from_reference_means():
    """The slow-drift arm: in-window stationary data that sits far from
    the reference (planning-time) means still fires ``shift_from``."""
    mon = RuntimeMonitor(N, window=64, min_rounds=32)
    rng = np.random.default_rng(5)
    t = DIST.sample(rng, (64, N))
    t[:, -1] *= 2.5  # worker 7 runs hot the whole window
    mon.observe_many(t)
    assert not mon.drift().fired  # both halves identically distributed
    base = np.full(N, DIST.mean())
    rep = mon.shift_from(base)
    assert rep.fired and rep.worker == N - 1
    # and quiet when the reference matches the stream
    base_hot = base.copy()
    base_hot[-1] *= 2.5
    assert not mon.shift_from(base_hot).fired


# --------------------------------------------------------------- controller
def test_controller_replans_on_step_change_and_improves():
    costs = np.ones(48)
    env0 = Env.iid(DIST, N)
    plan = Plan.build(costs, env0, N, scheme="xt")
    ctrl = AdaptiveController(
        AdaptConfig(window=64, min_rounds=32, check_every=4), plan, costs)
    rng = np.random.default_rng(11)
    new_plan = None
    for r in range(200):
        t = env0.sample(rng, (N,))
        t[:3] *= 3.0  # shifted regime from the first observed round
        got = ctrl.observe(t)
        if got is not None:
            new_plan = got
            break
    assert new_plan is not None, "controller never re-planned"
    assert ctrl.plan is new_plan and len(ctrl.swaps) == 1
    assert int(new_plan.x.sum()) == int(plan.total_units)
    assert new_plan.scheme == plan.scheme
    # the re-planned x is genuinely better under the true shifted regime
    eval_draws = env0.sample(np.random.default_rng(99), (4000, N))
    eval_draws[:, :3] *= 3.0
    tau_old = tau_hat_batch(np.asarray(plan.x, float), eval_draws).mean()
    tau_new = tau_hat_batch(np.asarray(new_plan.x, float), eval_draws).mean()
    assert tau_new < tau_old
    # swap event provenance is recorded
    ev = ctrl.swaps[0]
    assert ev.predicted_gain >= ctrl.cfg.min_gain
    np.testing.assert_array_equal(ev.x_old, plan.x)
    np.testing.assert_array_equal(ev.x_new, new_plan.x)


def test_controller_gain_gate_blocks_unprofitable_replan():
    """A uniform cluster-wide slowdown moves every mean (drift fires)
    but leaves the optimal *partition* unchanged — the predicted-gain
    gate must refuse the swap."""
    costs = np.ones(48)
    env0 = Env.iid(DIST, N)
    plan = Plan.build(costs, env0, N, scheme="xt")
    ctrl = AdaptiveController(
        AdaptConfig(window=64, min_rounds=32, check_every=4), plan, costs)
    rng = np.random.default_rng(13)
    for _ in range(300):
        assert ctrl.observe(2.0 * env0.sample(rng, (N,))) is None
    assert ctrl.swaps == [] and ctrl.checks > 0


# --------------------------------------------------------------- warm start
def test_spsg_warm_start_seeds_and_projects():
    x_opt = solve_scheme("xt", DIST, N, 1000, integer=False)
    res = spsg(Env.iid(DIST, N), N, 1000.0, n_iters=50, batch=16, rng=0,
               warm_start=x_opt)
    assert res.x.shape == (N,)
    assert res.x.sum() == pytest.approx(1000.0, abs=1e-6)
    # infeasible seeds are projected, not rejected
    res2 = spsg(Env.iid(DIST, N), N, 1000.0, n_iters=5, batch=8, rng=0,
                warm_start=np.full(N, 999.0))
    assert res2.x.sum() == pytest.approx(1000.0, abs=1e-6)
    # warm_start takes precedence over the legacy x0 spelling
    a = spsg(Env.iid(DIST, N), N, 1000.0, n_iters=5, batch=8, rng=0,
             x0=np.full(N, 1.0), warm_start=x_opt)
    b = spsg(Env.iid(DIST, N), N, 1000.0, n_iters=5, batch=8, rng=0,
             warm_start=x_opt)
    np.testing.assert_array_equal(a.x, b.x)


def test_solve_scheme_threads_warm_start_only_where_declared():
    x_seed = solve_scheme("xt", DIST, N, 1000)
    # spsg declares warm_start: a converged seed with few iterations
    # stays near the seed, while the cold solve starts uniform
    warm = solve_scheme("spsg", DIST, N, 1000, warm_start=x_seed)
    assert int(warm.sum()) == 1000
    # closed forms ignore the seed entirely — identical either way
    np.testing.assert_array_equal(
        solve_scheme("xt", DIST, N, 1000, warm_start=np.ones(N)),
        solve_scheme("xt", DIST, N, 1000))
    # and a cold spsg solve is unchanged by the new plumbing
    np.testing.assert_array_equal(
        solve_scheme("spsg", DIST, N, 1000),
        solve_scheme("spsg", DIST, N, 1000, warm_start=None))


def test_plan_build_warm_start_and_partition_key():
    costs = np.ones(16)
    p1 = Plan.build(costs, DIST, N, scheme="xt")
    p2 = Plan.build(costs, DIST, N, scheme="xt",
                    warm_start=np.ones(N))  # ignored by the closed form
    assert p1.partition_key() == p2.partition_key()
    assert isinstance(hash(p1.partition_key()), int)
    p3 = Plan.build(costs, DIST, N, scheme="xf")
    assert p3.partition_key() != p1.partition_key()


# ------------------------------------------------- hot-swap bit-identity (sim)
def _tree_hash(tree) -> str:
    import jax

    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _rng_hash(rng: np.random.Generator) -> str:
    return json.dumps(rng.bit_generator.state, sort_keys=True)


@pytest.fixture(scope="module")
def tiny_trainer_setup():
    from repro.configs import get_config

    cfg = get_config("gc-lm-110m").reduced(n_layers=1, d_model=64)
    env = Env.iid(DIST, 4)
    return cfg, env


def _make_trainer(cfg, env, scheme):
    from repro.train.trainer import TrainConfig, Trainer

    return Trainer(cfg, TrainConfig(total_steps=32), env, scheme=scheme,
                   global_batch=8, seed=0)


def test_noop_swap_is_bit_identical_to_never_swapping(tiny_trainer_setup):
    """Swap A -> B -> A between steps: state/RNG hashes unchanged at the
    swap epoch, and the continued run is bit-identical to a run that
    never swapped (the compiled step comes back from the cache)."""
    cfg, env = tiny_trainer_setup
    tr = _make_trainer(cfg, env, "xf")
    ref = _make_trainer(cfg, env, "xf")
    plan_a = tr.plan
    plan_b = Plan.build(tr.state.params, env, scheme="xt")
    assert plan_b.partition_key() != plan_a.partition_key()

    tr.run(2, log_every=0)
    state_h, rng_h = _tree_hash(tr.state), _rng_hash(tr.sim.rng)
    fn_a = tr.step_fn
    tr.swap_plan(plan_b)
    assert tr.plan is plan_b and tr.sim.plan is plan_b
    tr.swap_plan(plan_a)
    # no-op swap: optimizer/RNG state hashes unchanged, step fn reused
    assert _tree_hash(tr.state) == state_h
    assert _rng_hash(tr.sim.rng) == rng_h
    assert tr.step_fn is fn_a
    assert len(tr._step_cache) == 2

    tr.run(2, log_every=0)
    ref.run(4, log_every=0)
    assert _tree_hash(tr.state) == _tree_hash(ref.state)
    assert _rng_hash(tr.sim.rng) == _rng_hash(ref.sim.rng)
    assert [r["tau_coded"] for r in tr.history] == \
        [r["tau_coded"] for r in ref.history]


def test_swap_to_b_equals_fresh_run_started_on_b(tiny_trainer_setup):
    """A run that swaps to plan B at step k continues exactly as a run
    that was *constructed* on B and fast-forwarded to the same state +
    straggler-RNG position: the swap epoch carries no hidden state."""
    cfg, env = tiny_trainer_setup
    tr = _make_trainer(cfg, env, "xf")
    tr.run(2, log_every=0)
    fresh = _make_trainer(cfg, env, "xt")  # fresh.plan == plan B
    plan_b = fresh.plan
    # fast-forward the fresh run to the swap epoch: same train state,
    # same straggler-RNG position, same ledger length
    fresh.state = tr.state
    fresh.sim.rng.bit_generator.state = tr.sim.rng.bit_generator.state
    fresh.sim.ledger = list(tr.sim.ledger)

    tr.swap_plan(plan_b)
    tr.run(3, log_every=0)
    fresh.run(3, log_every=0)
    assert _tree_hash(tr.state) == _tree_hash(fresh.state)
    assert _rng_hash(tr.sim.rng) == _rng_hash(fresh.sim.rng)
    assert [r["tau_coded"] for r in tr.history[2:]] == \
        [r["tau_coded"] for r in fresh.history]


def test_swap_grads_bit_identical_every_straggler_count(tiny_trainer_setup):
    """Grad-fn level, sim mode: for EVERY straggler count 0..s_max the
    decoded gradients after swapping away and back (fresh compile) are
    bitwise equal to the originals."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import DataConfig, SyntheticTokens, coded_worker_batches
    from repro.train.coded import make_coded_grad_fn
    from repro.train.state import init_train_state

    cfg, env = tiny_trainer_setup
    state, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    n = 4
    plan_a = Plan.build(state.params, env, n, scheme="xf")
    plan_b = Plan.build(state.params, env, n, scheme="xt")
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8))
    wb_a = jnp.asarray(coded_worker_batches(data, 0, n, plan_a.s_max))
    wb_b = jnp.asarray(coded_worker_batches(data, 0, n, plan_b.s_max))

    fn_a1 = jax.jit(make_coded_grad_fn(cfg, plan_a, mode="sim"))
    before = []
    for u in range(plan_a.s_max + 1):
        times = np.ones(n)
        times[:u] = 1e6
        dec_w = jnp.asarray(plan_a.decode_weights(times), jnp.float32)
        before.append(fn_a1(state.params, wb_a, dec_w))
    # "swap": run plan B once, then rebuild plan A's fn from scratch
    fn_b = jax.jit(make_coded_grad_fn(cfg, plan_b, mode="sim"))
    fn_b(state.params, wb_b,
         jnp.asarray(plan_b.full_decode_weights(), jnp.float32))
    fn_a2 = jax.jit(make_coded_grad_fn(cfg, plan_a, mode="sim"))
    for u in range(plan_a.s_max + 1):
        times = np.ones(n)
        times[:u] = 1e6
        dec_w = jnp.asarray(plan_a.decode_weights(times), jnp.float32)
        after = fn_a2(state.params, wb_a, dec_w)
        for x, y in zip(jax.tree.leaves(before[u]), jax.tree.leaves(after)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_trainer_adaptive_replans_on_model_plans(tiny_trainer_setup):
    """Trainer(adapt=...) end to end at the controller level: the stored
    re-plan inputs are abstract (no pinned device arrays), a drifted
    stream produces a plan built against the live leaf shapes (with a
    FlatLayout), and swap_plan installs it."""
    import jax

    from repro.adapt import AdaptConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg, env = tiny_trainer_setup
    tr = Trainer(cfg, TrainConfig(), env, scheme="xt", global_batch=8,
                 seed=0, adapt=AdaptConfig(window=48, min_rounds=24,
                                           check_every=4))
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(tr.controller.params_or_costs))
    rng = np.random.default_rng(0)
    new_plan = None
    for _ in range(200):
        t = DIST.sample(rng, (4,))
        t[2:] *= 5.0  # half the fleet 5x slower than the planned-for env
        new_plan = tr.controller.observe(t)
        if new_plan is not None:
            break
    assert new_plan is not None, "controller never re-planned"
    assert new_plan.flat_layout is not None  # bound to the live leaves
    assert new_plan.partition_key() != tr.plan.partition_key()
    fn_before = tr.step_fn
    tr.swap_plan(new_plan)
    assert tr.plan is new_plan and tr.sim.plan is new_plan
    assert tr.step_fn is not fn_before
    # a MANUAL swap (not controller-initiated) re-baselines the
    # controller too: plan synced, window cleared
    plan_c = Plan.build(tr.state.params, env, scheme="xf")
    tr.controller.monitor.observe(np.ones(4))
    tr.swap_plan(plan_c)
    assert tr.controller.plan is plan_c
    assert len(tr.controller.monitor) == 0


# ------------------------------------------------- hot-swap bit-identity (spmd)
@pytest.mark.spmd
def test_swap_grads_bit_identical_spmd_psum_and_scatter():
    """The spmd twin of the test above, on an 8-device mesh: plan-A
    decoded grads are bitwise unchanged after a swap away and back, for
    every straggler count, for psum AND psum_scatter."""
    code = textwrap.dedent("""
        import json, jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import Env, Plan, ShiftedExponential
        from repro.dist.sharding import use_mesh, make_rules
        from repro.train.state import init_train_state
        from repro.train.coded import make_coded_grad_fn
        from repro.data.pipeline import DataConfig, SyntheticTokens, coded_worker_batches
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_config("gc-lm-110m").reduced(n_layers=1, d_model=64)
        state, _ = init_train_state(cfg, jax.random.PRNGKey(0))
        n = 4
        env = Env.iid(ShiftedExponential(mu=1e-3, t0=50.0), n)
        plan_a = Plan.build(state.params, env, n, scheme="xf")
        plan_b = Plan.build(state.params, env, n, scheme="xt")
        data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                          global_batch=8))
        wb_a = jnp.asarray(coded_worker_batches(data, 0, n, plan_a.s_max))
        wb_b = jnp.asarray(coded_worker_batches(data, 0, n, plan_b.s_max))
        out = {"devices": len(jax.devices()), "max_diff": 0.0}
        with use_mesh(mesh, make_rules(cfg)):
            for rm in ("psum", "psum_scatter"):
                mk = lambda p: jax.jit(make_coded_grad_fn(
                    cfg, p, mesh=mesh, mode="spmd", reduce_mode=rm))
                fn_a1 = mk(plan_a)
                before = []
                for u in range(plan_a.s_max + 1):
                    times = np.ones(n); times[:u] = 1e6
                    dw = jnp.asarray(plan_a.decode_weights(times), jnp.float32)
                    before.append(jax.tree.map(np.asarray,
                                               fn_a1(state.params, wb_a, dw)))
                fn_b = mk(plan_b)
                fn_b(state.params, wb_b,
                     jnp.asarray(plan_b.full_decode_weights(), jnp.float32))
                fn_a2 = mk(plan_a)
                for u in range(plan_a.s_max + 1):
                    times = np.ones(n); times[:u] = 1e6
                    dw = jnp.asarray(plan_a.decode_weights(times), jnp.float32)
                    after = fn_a2(state.params, wb_a, dw)
                    for x, y in zip(jax.tree.leaves(before[u]),
                                    jax.tree.leaves(after)):
                        d = float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
                        out["max_diff"] = max(out["max_diff"], d)
        print(json.dumps(out))
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 8
    assert res["max_diff"] == 0.0
