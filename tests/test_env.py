"""The first-class `Env` worker-population API.

Acceptance surface of the Env redesign: bare-distribution coercion is
*exactly* ``Env.iid`` (same objects, same solver outputs, same draw
streams), the env JSON round-trip is bit-identical inside
``Plan.to_dict``, heterogeneous-population order statistics agree with
a seeded event-simulator estimate, and declarative faults flow from the
env into every backend with the documented semantics (degradations
everywhere, deaths event-only).
"""
import json

import numpy as np
import pytest

from repro.core import (
    DegradedWorker,
    Env,
    MixtureStraggler,
    Plan,
    ScaledStraggler,
    ShiftedExponential,
    UniformStraggler,
    WorkerDeath,
    solve_scheme,
)
from repro.core.distributions import dist_from_dict, dist_to_dict
from repro.core.env import fault_from_dict, fault_to_dict

FAST = ShiftedExponential(mu=1e-3, t0=50.0)
SLOW = ScaledStraggler(base=FAST, factor=2.5)
COSTS = np.array([5.0, 3.0, 1.0, 2.0, 9.0, 4.0])


def het_env(n=8, n_slow=2) -> Env:
    return Env.heterogeneous([FAST] * (n - n_slow) + [SLOW] * n_slow)


# ------------------------------------------------------------- construction
def test_coerce_bare_dist_equals_iid_exactly():
    env = Env.coerce(FAST, 8)
    assert env == Env.iid(FAST, 8)
    assert env.is_iid and env.iid_dist == FAST and env.n_workers == 8
    # same object per worker, not copies with drifted fields
    assert all(d == FAST for d in env.dists)


def test_coerce_passthrough_list_and_errors():
    env = het_env()
    assert Env.coerce(env) is env
    assert Env.coerce(env, 8) is env
    with pytest.raises(ValueError):
        Env.coerce(env, 4)
    lst = Env.coerce([FAST, SLOW])
    assert lst.n_workers == 2 and not lst.is_iid
    with pytest.raises(ValueError):
        Env.coerce(FAST)  # bare dist needs n_workers
    with pytest.raises(TypeError):
        Env.coerce(42, 4)


def test_env_validates_workers_and_faults():
    with pytest.raises(ValueError):
        Env(dists=())
    with pytest.raises(TypeError):
        Env(dists=(FAST, "not-a-dist"))
    with pytest.raises(ValueError):
        Env.iid(FAST, 4).with_faults(WorkerDeath(9, at_round=0))
    with pytest.raises(ValueError):
        WorkerDeath(0)  # needs at_time or at_round
    with pytest.raises(ValueError):
        DegradedWorker(0, factor=0.0)


# ------------------------------------------------- coercion == bare-dist path
def test_solver_outputs_bit_identical_under_coercion():
    for scheme in ("xt", "xf", "spsg", "single-bcgc", "tandon-alpha"):
        x_dist = solve_scheme(scheme, FAST, 6, 600, rng=1)
        x_env = solve_scheme(scheme, Env.iid(FAST, 6), 6, 600, rng=1)
        np.testing.assert_array_equal(x_dist, x_env, err_msg=scheme)


def test_iid_env_sampling_stream_matches_bare_dist():
    a = FAST.sample(np.random.default_rng(7), (5, 8))
    b = Env.iid(FAST, 8).sample(np.random.default_rng(7), (5, 8))
    np.testing.assert_array_equal(a, b)


def test_plan_build_bit_identical_under_coercion():
    p_dist = Plan.build(COSTS, FAST, 4, scheme="xf", rng=3)
    p_env = Plan.build(COSTS, Env.iid(FAST, 4), scheme="xf", rng=3)
    np.testing.assert_array_equal(p_dist.x, p_env.x)
    np.testing.assert_array_equal(p_dist.leaf_levels, p_env.leaf_levels)
    np.testing.assert_array_equal(p_dist.b_rows, p_env.b_rows)
    assert p_dist.env == p_env.env  # the bare dist coerced to the same env
    # ledger parity on the same seed
    s1 = p_dist.simulate(FAST, 10, seed=5).summary()
    s2 = p_env.simulate(steps=10, seed=5).summary()  # bound env default
    assert s1 == s2


def test_plan_build_env_knows_n_workers_and_mismatch_raises():
    env = het_env(8)
    plan = Plan.build(COSTS, env, scheme="xt")
    assert plan.n_workers == 8 and plan.env is env
    with pytest.raises(ValueError):
        Plan.build(COSTS, env, 4, scheme="xt")


# ------------------------------------------------------------- serialization
def test_env_json_roundtrip_bit_identical():
    env = het_env().with_faults(WorkerDeath(0, at_round=5),
                                DegradedWorker(3, 6.0, from_round=10))
    blob = json.loads(json.dumps(env.to_dict()))
    env2 = Env.from_dict(blob)
    assert env2 == env
    assert env2.to_dict() == env.to_dict()  # byte-level fixed point


def test_env_roundtrip_inside_plan_to_dict_bit_identical():
    env = het_env().with_faults(DegradedWorker(7, 1.5))
    plan = Plan.build(COSTS, env, scheme="xt", rng=2)
    blob = plan.to_dict()
    j = json.loads(json.dumps(blob))      # through real JSON text
    plan2 = Plan.from_dict(j)
    assert plan2.env == plan.env
    assert plan2.to_dict() == blob        # whole-plan fixed point incl. env
    assert plan2.to_dict()["env"] == env.to_dict()


def test_pre_env_blobs_still_load():
    plan = Plan.build(COSTS, FAST, 4, scheme="xf")
    blob = plan.to_dict()
    del blob["env"]                        # a PR-1/PR-2 era snapshot
    old = Plan.from_dict(json.loads(json.dumps(blob)))
    assert old.env is None
    np.testing.assert_array_equal(old.b_rows, plan.b_rows)
    with pytest.raises(ValueError):
        old.simulate(steps=1)              # no bound env, none passed
    old.simulate(FAST, 1)                  # explicit env still fine


def test_nested_and_empirical_dist_serialization():
    from repro.core import EmpiricalStraggler

    emp = EmpiricalStraggler(trace=(1.0, 2.0, 3.5))
    mix = MixtureStraggler(components=(FAST, SLOW), weights=(0.25, 0.75))
    for d in (emp, mix, SLOW):
        back = dist_from_dict(json.loads(json.dumps(dist_to_dict(d))))
        assert back == d
    with pytest.raises(KeyError):
        dist_from_dict({"type": "NoSuchDist"})


def test_fault_serialization_roundtrip():
    for f in (WorkerDeath(2, at_time=10.0), WorkerDeath(1, at_round=3),
              DegradedWorker(0, 2.0, from_round=4)):
        assert fault_from_dict(json.loads(json.dumps(fault_to_dict(f)))) == f
    with pytest.raises(KeyError):
        fault_from_dict({"type": "Nope"})


# ------------------------------------------------------- order statistics
def test_het_order_stats_mc_vs_quadrature():
    env = het_env(6, 2)
    t_mc = env.expected_order_stats()
    t_q = env.expected_order_stats(method="quad")
    np.testing.assert_allclose(t_mc, t_q, rtol=0.015)
    tp_mc = env.inv_expected_inv_order_stats()
    tp_q = env.inv_expected_inv_order_stats(method="quad")
    np.testing.assert_allclose(tp_mc, tp_q, rtol=0.015)
    # sorted order statistics are nondecreasing
    assert (np.diff(t_mc) >= 0).all() and (np.diff(tp_q) >= 0).all()


def test_het_order_stats_agree_with_event_simulator():
    """E[T_(k)] of a non-identical population == what the event engine
    realizes: a single block at level s decodes at scale * T_(N-s)."""
    from repro.core.runtime import DEFAULT_COST
    from repro.sim import Block, ClusterSim

    n, rounds = 4, 8000
    env = Env.heterogeneous([FAST, FAST, FAST, SLOW])
    t_expect = env.expected_order_stats()
    scale = DEFAULT_COST.scale(n)
    times = env.sample(np.random.default_rng(17), (rounds, n))
    for s in range(n):
        sched = (Block(index=0, level=s, work=1.0),)
        res = ClusterSim(sched, env, n, wave=False).run(rounds, times=times)
        sim_mean = res.round_durations().mean() / scale
        assert abs(sim_mean / t_expect[n - s - 1] - 1.0) < 0.03, (
            s, sim_mean, t_expect[n - s - 1])


def test_iid_order_stats_delegate_to_closed_form():
    env = Env.iid(FAST, 8)
    np.testing.assert_array_equal(env.expected_order_stats(),
                                  FAST.expected_order_stats(8))
    np.testing.assert_array_equal(env.inv_expected_inv_order_stats(),
                                  FAST.inv_expected_inv_order_stats(8))


def test_static_degradation_enters_solver_view():
    env = Env.iid(FAST, 4).with_faults(DegradedWorker(3, 4.0))
    assert not env.is_iid  # the fault breaks population identity
    eff = env.effective_dists()
    assert eff[3] == ScaledStraggler(base=FAST, factor=4.0)
    assert eff[0] == FAST
    # the slow machine inflates the top order statistics
    t_fault = env.expected_order_stats()
    t_clean = Env.iid(FAST, 4).expected_order_stats()
    assert t_fault[-1] > t_clean[-1] * 1.5
    # ... and the optimized partition shifts mass toward coded levels
    x_fault = solve_scheme("xt", env, 4, 1000)
    x_clean = solve_scheme("xt", FAST, 4, 1000)
    assert x_fault[0] < x_clean[0]
    # sampling-based schemes see the same solver view as the closed
    # forms (solve_scheme routes through env.solver_view())
    xs_fault = solve_scheme("spsg", env, 4, 1000, rng=0)
    xs_clean = solve_scheme("spsg", FAST, 4, 1000, rng=0)
    assert not np.array_equal(xs_fault, xs_clean)
    # single-bcgc: a near-deterministic cluster wants no redundancy
    # (s=0) until one worker is permanently 10x slower, at which point
    # erasing it (s=1) must win — only visible through the solver view
    tight = UniformStraggler(lo=1.0, hi=1.2)
    x0 = solve_scheme("single-bcgc", Env.iid(tight, 4), 4, 1000)
    x1 = solve_scheme("single-bcgc",
                      Env.iid(tight, 4).with_faults(DegradedWorker(3, 10.0)),
                      4, 1000)
    assert x0[0] == 1000 and x1[1] == 1000, (x0, x1)


def test_solver_view_identity_and_fault_drop():
    env = Env.iid(FAST, 4)
    assert env.solver_view() is env          # fault-free: pass-through
    faulted = env.with_faults(WorkerDeath(0, at_round=0),
                              DegradedWorker(1, 2.0),
                              DegradedWorker(2, 3.0, from_round=5))
    view = faulted.solver_view()
    assert view.faults == ()                 # transient faults dropped
    assert view.dists[1] == ScaledStraggler(base=FAST, factor=2.0)
    assert view.dists[2] == FAST             # mid-run throttle: not static


def test_pooled_marginal():
    env = het_env(4, 1)
    pooled = env.pooled()
    assert isinstance(pooled, MixtureStraggler)
    want = (3 * FAST.mean() + SLOW.mean()) / 4
    assert abs(pooled.mean() / want - 1.0) < 1e-12
    assert abs(env.mean() / want - 1.0) < 1e-12
    # iid env pools to its own dist
    assert Env.iid(FAST, 4).pooled() == FAST


# ------------------------------------------------------------------ faults
def test_env_faults_absorbed_by_cluster_sim():
    from repro.sim import ClusterSim, schedule_from_x

    n = 4
    x = np.zeros(n)
    x[1] = 100.0                           # level 1: tolerates one death
    env = Env.iid(UniformStraggler(lo=1.0, hi=1.0), n).with_faults(
        WorkerDeath(0, at_round=0))
    res = ClusterSim(schedule_from_x(x), env, n, wave=False).run(rounds=3)
    assert not res.stalled
    # same env, two deaths: redundancy exhausted -> stall
    env2 = env.with_faults(WorkerDeath(1, at_round=0))
    res2 = ClusterSim(schedule_from_x(x), env2, n, wave=False).run(rounds=3)
    assert res2.stalled


def test_degradation_identical_across_backends():
    env = Env.iid(FAST, 4).with_faults(DegradedWorker(1, 3.0, from_round=2))
    plan = Plan.build(COSTS, env, scheme="xf")
    led = {}
    for backend in ("eq2", "event", "mc"):
        sim = plan.simulate(steps=6, seed=3, backend=backend)
        led[backend] = np.asarray([r["tau_coded"] for r in sim.ledger])
    np.testing.assert_allclose(led["eq2"], led["event"], rtol=1e-9)
    np.testing.assert_allclose(led["eq2"], led["mc"], rtol=2e-4)


def test_deaths_rejected_by_analytic_backends():
    env = Env.iid(FAST, 4).with_faults(WorkerDeath(0, at_round=0))
    plan = Plan.build(COSTS, env, scheme="uniform")
    with pytest.raises(ValueError):
        plan.simulate(steps=2, backend="eq2")
    with pytest.raises(ValueError):
        plan.simulate(steps=2, backend="mc")
    res = plan.simulate(steps=2, backend="event")   # realized, stalls
    assert not np.isfinite([r["tau_coded"] for r in res.ledger]).all()
    # the uncoded baseline waits on every worker, so it stalls too —
    # the ledger must not present coding as losing to a dead baseline
    assert all(not np.isfinite(r["tau_uncoded"]) for r in res.ledger)


def test_event_uncoded_stalls_only_from_death_round():
    env = Env.iid(FAST, 4).with_faults(WorkerDeath(2, at_round=3))
    plan = Plan.build(COSTS, env, scheme="uniform")
    res = plan.simulate(steps=5, backend="event")
    unc = [r["tau_uncoded"] for r in res.ledger]
    assert np.isfinite(unc[:3]).all() and not np.isfinite(unc[3:]).any()


# ------------------------------------------------------------------ traces
def test_env_from_trace_roundtrip(tmp_path):
    from repro.sim import Trace

    trace = Trace.record(het_env(4, 2), rounds=40, n_workers=4, seed=1)
    path = str(tmp_path / "trace.json")
    trace.save(path)
    env = Env.from_trace(path)
    assert env.n_workers == 4 and not env.is_iid
    # worker columns preserved: slow workers resample slow marginals
    assert env.dists[3].mean() > env.dists[0].mean()
    assert env == trace.to_env()
    pooled = Env.from_trace(path, per_worker=False)
    assert pooled.is_iid
    # from_trace envs serialize like any other env
    assert Env.from_dict(env.to_dict()) == env


def test_heterogeneous_sample_shape_contract():
    env = het_env(4, 1)
    with pytest.raises(ValueError):
        env.sample(0, (100,))              # no worker axis
    t = env.sample(0, (100, 4))
    assert t.shape == (100, 4)
