"""Checkpoint round-trip: bit-identity, exotic dtypes, atomicity.

The checkpoint layer is the wave loop's crash boundary (docs/ASYNC.md:
a quiesced step boundary is the only durable point), so its contract is
tested directly: save/load must be bit-exact for every dtype the train
state carries — including the uint-view path for ml_dtypes exotics
(bf16, fp8) that numpy's npz cannot store natively — ``latest_step``
must order numerically, and a crashed partial write (the ``.tmp``
staging dir) must never be picked up as the latest checkpoint.
"""
import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.checkpoint import (
    intact_steps,
    latest_step,
    load_checkpoint,
    restore_train_state,
    save_checkpoint,
)
from repro.checkpoint.ckpt import reset_discovery_warnings


def _tree(seed=0):
    """A TrainState-shaped pytree mixing native and exotic dtypes."""
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "emb": jnp.asarray(rng.standard_normal((16, 4)), jnp.bfloat16),
        },
        "opt": {
            "mu": jnp.asarray(rng.standard_normal((4, 8)), jnp.bfloat16),
            "nu": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
            "count": jnp.asarray(7, jnp.int32),
        },
        "step": jnp.asarray(42, jnp.int32),
    }


def _assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype, (xa.dtype, ya.dtype)
        # exotic dtypes compare through the uint view (no NaN!=NaN traps)
        if xa.dtype.kind not in "biufc":
            xa = xa.view({1: np.uint8, 2: np.uint16}[xa.dtype.itemsize])
            ya = ya.view(xa.dtype)
        assert np.array_equal(xa, ya)


def test_save_load_roundtrip_bitwise(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 42, tree, extra={"note": "x"})
    assert os.path.basename(path) == "step_00000042"
    restored = restore_train_state(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree),
        str(tmp_path))
    _assert_trees_bitwise(tree, restored)


def test_exotic_dtype_stored_as_uint_view(tmp_path):
    """bf16 leaves survive npz via the same-width uint view and come
    back as bf16, bit for bit — including NaN/inf payloads."""
    special = jnp.asarray([np.nan, np.inf, -np.inf, 0.0, -0.0, 1.5],
                          jnp.bfloat16)
    tree = {"x": special}
    save_checkpoint(str(tmp_path), 0, tree)
    arrays, meta = load_checkpoint(str(tmp_path), 0)
    assert meta["dtypes"]["x"] == "bfloat16"
    assert str(arrays["x"].dtype) == "bfloat16"
    assert np.array_equal(arrays["x"].view(np.uint16),
                          np.asarray(special).view(np.uint16))
    # and the raw npz on disk holds the uint view (npz-safe storage)
    with np.load(os.path.join(str(tmp_path), "step_00000000",
                              "arrays.npz")) as z:
        assert z["x"].dtype == np.uint16


def test_latest_step_orders_numerically(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (3, 100, 20):
        save_checkpoint(str(tmp_path), s, tree)
    assert latest_step(str(tmp_path)) == 100
    arrays, meta = load_checkpoint(str(tmp_path))   # step=None -> latest
    assert meta["step"] == 100
    assert latest_step(str(tmp_path / "nope")) is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "nope"))


def test_crashed_partial_write_not_latest(tmp_path):
    """A .tmp staging dir left by a crash is invisible to latest_step
    and is swept (not merged into) by the next save of that step."""
    tree = {"x": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), 5, tree)
    crash = tmp_path / "step_00000009.tmp"
    crash.mkdir()
    (crash / "arrays.npz").write_bytes(b"partial garbage")
    assert latest_step(str(tmp_path)) == 5
    arrays, meta = load_checkpoint(str(tmp_path))
    assert meta["step"] == 5
    # finishing step 9 replaces the stale staging dir atomically
    save_checkpoint(str(tmp_path), 9, {"x": jnp.arange(4.0) + 1})
    assert latest_step(str(tmp_path)) == 9
    assert not crash.exists()
    arrays, _ = load_checkpoint(str(tmp_path), 9)
    assert np.array_equal(arrays["x"], np.arange(4.0) + 1)


def test_resave_overwrites_step(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((3,))})
    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones((3,))})
    arrays, _ = load_checkpoint(str(tmp_path), 1)
    assert np.array_equal(arrays["x"], np.ones(3))


def test_restore_validates_shape_and_missing(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros((3,))})
    with pytest.raises(ValueError, match="shape"):
        restore_train_state({"x": jnp.zeros((4,))}, str(tmp_path))
    with pytest.raises(KeyError, match="missing leaf"):
        restore_train_state({"y": jnp.zeros((3,))}, str(tmp_path))


# ------------------------------------------------------ discovery hardening
def test_stray_entries_skipped_with_one_shot_warning(tmp_path):
    """latest_step/load_checkpoint survive the debris a crashed or
    foreign writer leaves: non-numeric step_* names, step files (not
    dirs), dirs missing meta.json or the payload — each skipped with
    exactly one warning, and only the newest *intact* checkpoint wins."""
    reset_discovery_warnings()
    save_checkpoint(str(tmp_path), 7, {"x": jnp.arange(3.0)})
    (tmp_path / "step_banana").mkdir()              # non-numeric suffix
    (tmp_path / "step_00000zzz").write_text("?")    # stray file
    nometa = tmp_path / "step_00000900"
    nometa.mkdir()                                   # newer, but no meta.json
    (nometa / "arrays.npz").write_bytes(b"x")
    nopay = tmp_path / "step_00000800"
    nopay.mkdir()                                    # meta but no payload
    (nopay / "meta.json").write_text("{}")
    with pytest.warns(RuntimeWarning, match="skipping"):
        assert latest_step(str(tmp_path)) == 7
    arrays, meta = load_checkpoint(str(tmp_path))
    assert meta["step"] == 7
    assert np.array_equal(arrays["x"], np.arange(3.0))
    # one-shot: the same debris does not warn again
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        assert latest_step(str(tmp_path)) == 7


def test_torn_newest_falls_back_to_intact(tmp_path):
    """A newest step dir with a corrupt arrays.npz is skipped (warned
    once) and load_checkpoint(step=None) falls back to the previous
    intact checkpoint; the explicit-step load stays strict."""
    reset_discovery_warnings()
    save_checkpoint(str(tmp_path), 3, {"x": jnp.arange(2.0)})
    save_checkpoint(str(tmp_path), 9, {"x": jnp.arange(2.0) + 1})
    torn = tmp_path / "step_00000009" / "arrays.npz"
    torn.write_bytes(b"not an npz at all")
    assert latest_step(str(tmp_path)) == 9  # structurally intact...
    with pytest.warns(RuntimeWarning, match="unreadable"):
        arrays, meta = load_checkpoint(str(tmp_path))  # ...but unloadable
    assert meta["step"] == 3
    with pytest.raises(Exception):
        load_checkpoint(str(tmp_path), 9)  # explicit step: strict
    # every candidate torn -> a clear FileNotFoundError, not a crash
    torn3 = tmp_path / "step_00000003" / "arrays.npz"
    torn3.write_bytes(b"also garbage")
    reset_discovery_warnings()
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="no loadable"):
            load_checkpoint(str(tmp_path))


def test_intact_steps_reports_kind(tmp_path):
    from repro.checkpoint import CodedSpec, save_coded_checkpoint

    save_checkpoint(str(tmp_path), 1, {"x": jnp.arange(2.0)})
    save_coded_checkpoint(str(tmp_path), 4, {"x": jnp.arange(8.0)},
                          CodedSpec(n_shards=4, parity=1))
    assert intact_steps(str(tmp_path)) == [(4, "coded"), (1, "monolithic")]
    # the monolithic loader refuses a coded dir explicitly...
    with pytest.raises(ValueError, match="erasure-coded"):
        load_checkpoint(str(tmp_path), 4)
    # ...and skips it (warning once) when scanning for the newest
    reset_discovery_warnings()
    with pytest.warns(RuntimeWarning, match="erasure-coded"):
        arrays, meta = load_checkpoint(str(tmp_path))
    assert meta["step"] == 1


# --------------------------------------------------------- crash atomicity
class _CrashAt:
    """Crash hook that raises at one named durability stage."""

    def __init__(self, stage):
        self.stage = stage
        self.seen = []

    def __call__(self, stage):
        self.seen.append(stage)
        if stage == self.stage:
            raise KeyboardInterrupt(f"injected crash at {stage}")


CRASH_STAGES = ["arrays_synced", "meta_synced", "payload_synced",
                "staging_synced", "renamed", "parent_synced"]


@pytest.mark.parametrize("stage", CRASH_STAGES)
def test_crash_at_every_boundary_keeps_previous_checkpoint(tmp_path, stage):
    """Kill the writer at each fsync/rename boundary: the previous
    checkpoint must stay loadable, and the next save must recover
    (sweeping any orphaned staging dir) regardless of where the crash
    landed."""
    reset_discovery_warnings()
    old = {"x": jnp.arange(4.0)}
    new = {"x": jnp.arange(4.0) * 10}
    save_checkpoint(str(tmp_path), 1, old)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(str(tmp_path), 2, new, _crash_hook=_CrashAt(stage))
    # previous checkpoint survives the crash at every stage
    arrays, meta = load_checkpoint(str(tmp_path), 1)
    assert np.array_equal(arrays["x"], np.arange(4.0))
    # discovery never trips over the debris; crashes after the rename
    # legitimately expose the (fully written) new checkpoint
    arrays, meta = load_checkpoint(str(tmp_path))
    assert meta["step"] in (1, 2)
    if stage in ("renamed", "parent_synced"):
        assert meta["step"] == 2
    # the next save sweeps any orphan and lands cleanly
    save_checkpoint(str(tmp_path), 3, new)
    assert latest_step(str(tmp_path)) == 3
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))
    arrays, _ = load_checkpoint(str(tmp_path), 3)
    assert np.array_equal(arrays["x"], np.arange(4.0) * 10)


def test_crash_hook_stage_order(tmp_path):
    """The durability boundaries fire in the documented order — the
    atomicity argument depends on it (files before staging dir before
    rename before parent)."""
    hook = _CrashAt(stage=None)
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)}, _crash_hook=hook)
    assert hook.seen == CRASH_STAGES


def test_meta_json_is_readable(tmp_path):
    save_checkpoint(str(tmp_path), 12, {"x": jnp.zeros((2,), jnp.bfloat16)},
                    extra={"arch": "gc-lm-110m"})
    with open(os.path.join(str(tmp_path), "step_00000012",
                           "meta.json")) as f:
        meta = json.load(f)
    assert meta["step"] == 12 and meta["n_leaves"] == 1
    assert meta["extra"]["arch"] == "gc-lm-110m"
    assert meta["dtypes"]["x"] == "bfloat16"
