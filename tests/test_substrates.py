"""Substrate layers: data pipeline, optimizer, checkpointing, sharding
rules, baselines sanity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BernoulliStraggler, ShiftedExponential, ferdinand_x,
                        scheme_bank, single_bcgc, tandon_alpha_level)
from repro.checkpoint.ckpt import (latest_step, load_checkpoint,
                                   restore_train_state, save_checkpoint)
from repro.data.pipeline import DataConfig, SyntheticTokens, coded_worker_batches
from repro.optim.optim import (adamw_init, adamw_update, clip_by_global_norm,
                               cosine_schedule, linear_schedule, sgd_init,
                               sgd_update)


# ------------------------------------------------------------------- data
def test_shards_deterministic_and_partition():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=12)
    data = SyntheticTokens(cfg)
    s1 = data.shard(5, 3, 4)
    s2 = data.shard(5, 3, 4)
    np.testing.assert_array_equal(s1, s2)
    assert s1.shape == (3, 17)
    assert s1.max() < 97 and s1.min() >= 0
    # different steps/shards differ
    assert not np.array_equal(s1, data.shard(6, 3, 4))
    assert not np.array_equal(s1, data.shard(5, 2, 4))


def test_coded_worker_batches_shape_and_overlap():
    data = SyntheticTokens(DataConfig(vocab=50, seq_len=8, global_batch=8))
    wb = coded_worker_batches(data, 0, 4, 2)
    assert wb.shape == (4, 3, 2, 9)
    # worker 0 slot 1 == worker 1 slot 0 (both are shard 1)
    np.testing.assert_array_equal(wb[0, 1], wb[1, 0])


def test_zipf_stream_learnable_structure():
    data = SyntheticTokens(DataConfig(vocab=101, seq_len=512, global_batch=2))
    b = data.batch(0)
    counts = np.bincount(b.ravel(), minlength=101)
    assert counts[:10].sum() > counts[50:60].sum()  # Zipf head heavier


# ------------------------------------------------------------------ optim
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(grads, opt, params, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_minimizes():
    params = {"w": jnp.asarray([4.0])}
    opt = sgd_init(params)
    for _ in range(150):
        params, opt = sgd_update({"w": 2 * params["w"]}, opt, params, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clip_and_schedules():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) <= 1.0 + 1e-5
    assert float(norm) > 1.0
    assert float(cosine_schedule(0, 1.0, 10, 100)) == 0.0
    assert float(cosine_schedule(10, 1.0, 10, 100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, 1.0, 10, 100)) == pytest.approx(0.1, rel=1e-2)
    assert float(linear_schedule(100, 1.0, 10, 100)) == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros(2), jnp.asarray(3)]}}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 7, tree, extra={"note": "hi"})
    save_checkpoint(d, 9, tree)
    assert latest_step(d) == 9
    arrays, meta = load_checkpoint(d, 7)
    assert meta["extra"]["note"] == "hi"
    restored = restore_train_state(tree, d, 9)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_train_state({"a": jnp.zeros((3,))}, d)


# --------------------------------------------------------------- sharding
def test_pspec_divisibility_fallback():
    import jax as _jax
    from repro.dist.sharding import make_rules, pspec_for_axes, use_mesh
    mesh = _jax.make_mesh((1, 1), ("data", "model"),
                          axis_types=(_jax.sharding.AxisType.Auto,) * 2)
    with use_mesh(mesh, make_rules()):
        # everything divisible by 1 -> sharded entries appear
        spec = pspec_for_axes(("batch", "embed", "heads"), (8, 16, 4))
        assert spec == _jax.sharding.PartitionSpec("data", None, "model")


def test_pspec_drops_nondivisible():
    import jax as _jax
    from repro.dist.sharding import make_rules, pspec_for_axes, use_mesh
    if len(_jax.devices()) != 1:
        pytest.skip("single-device layout assumed")
    mesh = _jax.make_mesh((1, 1), ("data", "model"),
                          axis_types=(_jax.sharding.AxisType.Auto,) * 2)
    with use_mesh(mesh, make_rules()):
        spec = pspec_for_axes(("heads",), (7,))  # 7 % 1 == 0 -> sharded
        assert spec == _jax.sharding.PartitionSpec("model")


# -------------------------------------------------------------- baselines
def test_baselines_reasonable():
    dist = ShiftedExponential(mu=1e-3, t0=50.0)
    x = single_bcgc(dist, 8, 100)
    assert x.sum() == 100 and (x > 0).sum() == 1
    lvl = tandon_alpha_level(dist, 8)
    assert 0 <= lvl <= 7
    xf = ferdinand_x(dist, 8, 100, n_layers=100)
    assert np.isclose(xf.sum(), 100)
    bank = scheme_bank(dist, 8, 100)
    assert len(bank) == 4


def test_bernoulli_degenerates_to_full_straggler():
    """With a two-point distribution the best single level is s ~= expected
    straggler count — sanity that the model includes the full-straggler
    regime of [1]."""
    dist = BernoulliStraggler(p_straggle=0.25, t_fast=1.0, t_slow=1e6)
    x = single_bcgc(dist, 8, 100, n_samples=20000)
    s_star = int(np.nonzero(x)[0][0])
    assert s_star >= 2  # tolerates at least the typical straggler count
