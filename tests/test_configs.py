"""Guard the exact assigned architecture numbers (vs typos/drift)."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
    "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
}


def test_all_ten_assigned_archs_registered():
    archs = set(list_archs())
    assert set(ASSIGNED) <= archs


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_numbers(arch):
    l, d, h, kv, ff, v = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == l
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab == v


def test_arch_specific_features():
    assert get_config("gemma-2b").head_dim == 256
    ds = get_config("deepseek-v3-671b")
    assert ds.mla is not None and ds.mtp_depth == 1
    moe = [l.moe for l in ds.layers if l.moe]
    assert len(moe) == 58 and moe[0].num_experts == 256 and moe[0].top_k == 8
    assert moe[0].num_shared == 1 and moe[0].d_ff == 2048
    mx = get_config("mixtral-8x22b")
    assert all(l.moe and l.moe.num_experts == 8 and l.moe.top_k == 2
               for l in mx.layers)
    assert all(l.window == 4096 for l in mx.layers)
    g3 = get_config("gemma3-27b")
    assert sum(l.window is None for l in g3.layers) == 10  # ~1 in 6 global
    g2 = get_config("gemma2-27b")
    assert g2.attn_softcap == 50.0 and g2.final_softcap == 30.0
    jb = get_config("jamba-v0.1-52b")
    assert sum(l.mixer == "attn" for l in jb.layers) == 4  # 1:7
    assert sum(l.moe is not None for l in jb.layers) == 16  # every other
    xl = get_config("xlstm-1.3b")
    assert sum(l.mixer == "slstm" for l in xl.layers) == 6  # 7:1
    assert all(not l.use_ffn for l in xl.layers)
    wh = get_config("whisper-base")
    assert wh.encoder is not None and wh.encoder.n_layers == 6
    assert all(l.cross_source for l in wh.layers)
    vl = get_config("llama-3.2-vision-11b")
    assert sum(l.mixer == "cross_attn" for l in vl.layers) == 8
    qw = get_config("qwen1.5-32b")
    assert qw.qkv_bias


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_param_counts_match_names():
    """Abstract param counts are in the right ballpark for the names."""
    import jax
    from repro.train.state import abstract_train_state
    from repro.models.params import count_params
    expect = {"deepseek-v3-671b": (600e9, 750e9),
              "mixtral-8x22b": (120e9, 160e9),
              "gemma3-27b": (24e9, 32e9),
              "gemma2-27b": (24e9, 32e9),
              "qwen1.5-32b": (28e9, 36e9),
              "gemma-2b": (2e9, 3.5e9),
              "llama-3.2-vision-11b": (8e9, 13e9),
              "xlstm-1.3b": (1.0e9, 2.5e9),
              "whisper-base": (0.05e9, 0.12e9)}
    for arch, (lo, hi) in expect.items():
        shapes, _ = abstract_train_state(get_config(arch))
        n = count_params(shapes.params)
        assert lo <= n <= hi, (arch, n)
