"""Arrival-process contracts for the serving loop.

``poisson_arrivals`` and ``trace_arrivals`` feed every serving
benchmark's open-loop load model; the policy comparisons there are only
apples-to-apples if the streams are deterministic under a seed, sorted,
and hit their advertised rates.  Pure numpy — no jax.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sim.arrivals import poisson_arrivals, trace_arrivals

_EX = int(os.environ.get("REPRO_PROPERTY_EXAMPLES", "10"))


# -------------------------------------------------------------- poisson
def test_poisson_seeded_determinism():
    a = poisson_arrivals(200, 0.5, seed=7)
    b = poisson_arrivals(200, 0.5, seed=7)
    assert np.array_equal(a, b)
    c = poisson_arrivals(200, 0.5, seed=8)
    assert not np.array_equal(a, c)


def test_poisson_rng_continuation():
    """Passing an rng continues one stream: two halves drawn from the
    same generator concatenate to the single-call stream."""
    whole = poisson_arrivals(100, 2.0, seed=3)
    rng = np.random.default_rng(3)
    first = poisson_arrivals(50, 2.0, rng=rng)
    second = poisson_arrivals(50, 2.0, rng=rng, start=float(first[-1]))
    assert np.array_equal(whole[:50], first)
    np.testing.assert_allclose(whole[50:], second, rtol=1e-12)


@settings(max_examples=2 * _EX, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.1, 1.0, 10.0]),
       st.sampled_from([0.0, 5.0]))
def test_poisson_monotone_and_positive_gaps(seed, rate, start):
    t = poisson_arrivals(64, rate, seed=seed, start=start)
    assert t.shape == (64,)
    assert t[0] >= start
    assert np.all(np.diff(t) >= 0.0)


def test_poisson_rate_matches_empirical_mean():
    """Mean gap over a long stream ~ 1/rate (law of large numbers; the
    tolerance is ~4 sigma for n=20000 exponential gaps)."""
    for rate in (0.25, 2.0, 40.0):
        t = poisson_arrivals(20_000, rate, seed=11)
        gaps = np.diff(np.concatenate([[0.0], t]))
        assert abs(gaps.mean() * rate - 1.0) < 4.0 / np.sqrt(20_000)


def test_poisson_validation():
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(10, 0.0)
    with pytest.raises(ValueError, match="n must"):
        poisson_arrivals(-1, 1.0)
    assert poisson_arrivals(0, 1.0).shape == (0,)


# ---------------------------------------------------------------- trace
def test_trace_roundtrip_identity():
    """Replaying a recorded stream with no options is the stream itself
    (re-anchored at its own origin)."""
    t = poisson_arrivals(50, 1.5, seed=2, start=100.0)
    out = trace_arrivals(t)
    np.testing.assert_allclose(out, t - t[0], rtol=0, atol=0)
    # and re-offsetting restores the original exactly
    np.testing.assert_allclose(trace_arrivals(t, start=float(t[0])), t,
                               rtol=1e-12)


def test_trace_truncates_and_cycles():
    base = [0.0, 1.0, 3.0]
    assert trace_arrivals(base, n=2).tolist() == [0.0, 1.0]
    cycled = trace_arrivals(base, n=7)
    assert cycled.shape == (7,)
    assert np.all(np.diff(cycled) >= 0.0)
    # each repetition is the same burst shape shifted past the last span
    span = 3.0 + 1.5   # trace span + mean gap
    np.testing.assert_allclose(cycled[3:6], np.asarray(base) + span)


def test_trace_rate_rescale_hits_target():
    t = poisson_arrivals(400, 3.0, seed=5)
    for target in (0.5, 3.0, 12.0):
        out = trace_arrivals(t, rate=target)
        realized = (out.size - 1) / float(out[-1] - out[0])
        assert realized == pytest.approx(target, rel=1e-9)


@settings(max_examples=2 * _EX, deadline=None)
@given(st.data())
def test_trace_properties(data):
    """Sorted in, sorted out; n honored; burst shape preserved under
    rescale (gap ratios invariant)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    t = np.sort(rng.uniform(0.0, 100.0, size=int(rng.integers(2, 40))))
    n = data.draw(st.integers(1, 80))
    out = trace_arrivals(t, n=n)
    assert out.shape == (n,)
    assert np.all(np.diff(out) >= 0.0)
    rescaled = trace_arrivals(t, rate=2.0)
    if t[-1] > t[0]:
        g0, g1 = np.diff(t - t[0]), np.diff(rescaled)
        mask = g0 > 0
        if mask.any():
            ratios = g1[mask] / g0[mask]
            np.testing.assert_allclose(ratios, ratios[0], rtol=1e-9)


def test_trace_validation():
    with pytest.raises(ValueError, match="sorted"):
        trace_arrivals([0.0, 2.0, 1.0])
    with pytest.raises(ValueError, match="empty"):
        trace_arrivals([])
    with pytest.raises(ValueError, match="rate"):
        trace_arrivals([0.0, 1.0], rate=-1.0)
